#!/usr/bin/env python
"""Pydocstyle-lite: AST docstring gate for the public API surface.

Usage: python scripts/check_docstrings.py PATH [PATH...]
       (PATH = a .py file or a package directory, scanned non-recursively)

Rules (a pragmatic subset of pydocstyle D1xx, no third-party deps):
  * every module needs a module docstring;
  * every public (non-underscore) module-level function and class needs a
    docstring;
  * every public method of a public class needs a docstring (dunders other
    than __init__ are exempt; __init__ is exempt when the class docstring
    documents construction, i.e. the class has one).

Exit 0 = clean; 1 = violations (listed). Wired into scripts/ci.sh so the
`repro.api` exports and the scheduler's SuperbatchScheduler/BatchProgram
surface keep arg/return documentation.
"""
from __future__ import annotations

import ast
import os
import sys


def _has_doc(node) -> bool:
    return ast.get_docstring(node) is not None


def check_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    errs = []
    if not _has_doc(tree):
        errs.append(f"{path}:1: missing module docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_") and not _has_doc(node):
                errs.append(f"{path}:{node.lineno}: missing docstring on "
                            f"function {node.name}")
        elif isinstance(node, ast.ClassDef):
            if node.name.startswith("_"):
                continue
            if not _has_doc(node):
                errs.append(f"{path}:{node.lineno}: missing docstring on "
                            f"class {node.name}")
            for sub in node.body:
                if not isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    continue
                name = sub.name
                if name.startswith("__") and name.endswith("__"):
                    continue                      # dunders exempt
                if name.startswith("_"):
                    continue
                if not _has_doc(sub):
                    errs.append(f"{path}:{sub.lineno}: missing docstring "
                                f"on method {node.name}.{name}")
    return errs


def collect(args: list[str]) -> list[str]:
    files = []
    for a in args:
        if os.path.isdir(a):
            files += sorted(os.path.join(a, f) for f in os.listdir(a)
                            if f.endswith(".py"))
        elif a.endswith(".py"):
            files.append(a)
    return files


def main() -> int:
    files = collect(sys.argv[1:])
    if not files:
        print(__doc__)
        return 2
    errs = []
    for path in files:
        errs += check_file(path)
    for e in errs:
        print(f"check-docstrings: {e}")
    print(f"check-docstrings: {len(files)} files, "
          f"{'FAIL: ' + str(len(errs)) + ' missing' if errs else 'ok'}")
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
